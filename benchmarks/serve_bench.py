"""Continuous-batching serve benchmark: Poisson load through PagedEngine.

Drives a >=16-request Poisson workload through the continuous-batching
engine, reports throughput and p50/p99 latency (in scheduler iterations)
plus the NSB hot-set hit rate, replays the captured multi-tenant trace
through the NVR simulator, and compares against the single-batch baseline
``Engine`` serving the same workload in fixed FIFO batches.

Baseline latency model: batches form in arrival order, a batch starts
once the previous batch drained AND all its members have arrived, and
every member waits for the whole batch to finish (lockstep decode, no
admission mid-batch) — exactly the behaviour continuous batching removes.
Baseline ticks count model iterations (1 prefill + max-gen decode steps)
so both engines are measured in the same unit.

Capture-methodology caveat: both engines record layer-0 traffic only,
but the continuous engine records its *actual* layer-0 TopK selections
(real decode queries, inside the paged step) while the single-batch
``Engine`` records a layer-0 ones-query proxy (its real selections
happen inside jit and are not observable).  The
``*_single_batch`` NVR/NSB numbers are therefore proxy-traffic figures —
directly comparable latency-wise, indicative (not identical-methodology)
traffic-wise; the serve-layer headline comparison is the latency pair.

A second scenario, ``prefix_bench``, drives N requests over a handful of
shared system prompts through the engine with and without cross-request
prefix caching: prefill-token savings, TTFT/throughput deltas, the
cached-page hit rate, and the captured-trace NVR replay on genuinely
shared physical ids.

A third, ``tp_serve_bench``, runs the same Poisson load through the
tensor-parallel engine (KV-head-sharded pools + QKV weights over a
("model",) mesh) at tp=1 vs tp=2/4: tokens/s per tp level, bitwise
cross-tp parity of every request's tokens and logits asserted in-run,
pool donation asserted under sharding, and per-shard NSB hit rates.
The sharded levels need forced host devices on CPU.

A fifth, ``spill_bench``, oversubscribes the HBM pool (aggregate demand
pages far beyond ``n_pages``) so the scheduler must preempt, and
compares the recompute eviction policy against the host spill tier
(swap-out/swap-in, optionally with runahead fetch-back): tokens asserted
bitwise-identical across policies in-run, resume-TTFT (re-admission to
next new token, in iterations) and tokens/s per policy, swap traffic and
the int8-tier dequantisation error bound reported.

A fourth, ``runahead_bench``, serves the shared-prefix Poisson load
through the online-runahead engine at runahead off / imp / nvr: token
streams and logits asserted bitwise-identical across modes in-run, NSB
hit-rate lift of nvr over the demand-LRU (no-runahead) tier asserted,
prediction accuracy / coverage / over-fetch reported, and a modeled
memory-stall throughput gain derived from the machine model's latencies
(DRAM miss vs NSB hit) on the identical demand page stream.

  PYTHONPATH=src python -m benchmarks.serve_bench
  PYTHONPATH=src python -m benchmarks.run serve_bench prefix_bench
  PYTHONPATH=src python -m benchmarks.run runahead_bench
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.run tp_serve_bench
"""

from __future__ import annotations

import os
import statistics
import time

import numpy as np

SCALE = float(os.environ.get("BENCH_SCALE", "0.5"))
from .paths import results_dir


def _workload(cfg, n_req: int, seed: int = 0):
    from repro.serve.scheduler import PoissonArrivals

    rng = np.random.default_rng(seed)
    arrivals = PoissonArrivals(n_req, rate=0.6, prompt_len=(8, 24),
                               gen_len=(4, 10), seed=seed)
    return [(t, rng.integers(1, cfg.vocab, size=p), g)
            for t, p, g in arrivals]


def _run_continuous(cfg, params, workload):
    from repro.serve.engine import PagedEngine

    n_logical = 48 // cfg.kv_page
    eng = PagedEngine(cfg, params, max_len=48,
                      n_pages=1 + 4 * n_logical,   # < max_batch full-size:
                      max_batch=8, chunk=8,        # real eviction pressure
                      nsb_pages=32, capture_trace=True)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    wall = time.perf_counter() - t0
    return eng, wall


def _run_single_batch(cfg, params, workload, batch_size: int = 8):
    """The same requests through the lockstep baseline, in FIFO batches."""
    import jax.numpy as jnp

    from repro.serve.engine import Engine

    merged = None
    latencies = []
    nsb_hits = nsb_misses = 0
    tick = 0.0
    t0 = time.perf_counter()
    tokens_out = 0
    for b0 in range(0, len(workload), batch_size):
        group = workload[b0:b0 + batch_size]
        plen = max(len(p) for _, p, _ in group)
        gen = max(g for _, _, g in group)
        toks = np.zeros((len(group), plen), dtype=np.int32)
        for i, (_, p, _) in enumerate(group):
            toks[i, :len(p)] = p           # right-padded lockstep prompt
        pg = cfg.kv_page
        max_len = -(-(plen + gen) // pg) * pg      # page-aligned
        eng = Engine(cfg, params, max_len=max_len, sparse=True,
                     nsb_pages=32, capture_trace=True)
        eng.generate({"tokens": jnp.asarray(toks)}, gen)
        tokens_out += len(group) * gen
        nsb_hits += eng.stats.nsb_hits
        nsb_misses += eng.stats.nsb_misses
        if merged is None:
            merged = eng.recorder
        else:
            merged.events.extend(eng.recorder.events)
            merged.rids.extend(eng.recorder.rids)
            merged.steps.extend(eng.recorder.steps)
            merged.shards.extend(eng.recorder.shards)
            merged.n_rows = max(merged.n_rows, eng.recorder.n_rows)
        # latency model: start when drained AND every member has arrived
        start = max(tick, max(t for t, _, _ in group))
        tick = start + 1 + gen             # 1 prefill + gen decode iters
        latencies += [tick - t for t, _, _ in group]
    wall = time.perf_counter() - t0
    hit_rate = nsb_hits / max(1, nsb_hits + nsb_misses)
    return merged, latencies, hit_rate, wall, tokens_out


def serve_bench():
    """Registered in benchmarks.run as ``serve_bench``."""
    import jax

    from repro.configs import get_config
    from repro.core.nvr import demand_miss_reduction
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.models import api
    from repro.serve.engine import percentile

    cfg = get_config("qwen2-1.5b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(16, int(32 * SCALE))
    workload = _workload(cfg, n_req)

    eng, cb_wall = _run_continuous(cfg, params, workload)
    m = eng.metrics()
    cb_red = demand_miss_reduction(eng.captured_trace())
    # finished-only, same filter metrics() applies — keep one definition
    cb_lat = [r.latency() for r in eng.requests.values()
              if r.finished_at >= 0]
    # nearest-rank percentiles are actual order statistics of the sample
    for q in (0.50, 0.99):
        assert percentile(cb_lat, q) in cb_lat, \
            f"p{int(q * 100)} is not an order statistic"

    sb_stream, sb_lat, sb_hit, sb_wall, sb_tokens = _run_single_batch(
        cfg, params, workload)
    sb_red = demand_miss_reduction(sb_stream.to_trace())

    rows = []
    for rid in sorted(eng.requests):
        r = eng.requests[rid]
        rows.append((rid, f"{r.arrival:.2f}", f"{r.admitted_at:.0f}",
                     f"{r.first_token_at:.0f}", f"{r.finished_at:.0f}",
                     r.prompt_len, len(r.out_tokens), r.n_preemptions,
                     f"{r.latency():.0f}", f"{sb_lat[rid]:.0f}"))

    headline = {
        "n_requests": float(n_req),
        "throughput_tok_per_s": m["tokens_out"] / cb_wall,
        "p50_latency_iters": m["p50_latency"],
        "p99_latency_iters": m["p99_latency"],
        "p50_tpot_iters": m["p50_tpot"],
        "p99_tpot_iters": m["p99_tpot"],
        "p50_latency_single_batch": percentile(sb_lat, 0.50),
        "p99_latency_single_batch": percentile(sb_lat, 0.99),
        "mean_latency_speedup_x": (statistics.mean(sb_lat)
                                   / statistics.mean(cb_lat)),
        "nsb_hot_hit_rate": m["nsb_hot_hit_rate"],
        "nsb_hit_rate_single_batch_proxy": sb_hit,
        "preemptions": float(m["preemptions"]),
        "nvr_miss_reduction_captured": cb_red,
        "nvr_miss_reduction_single_batch_proxy": sb_red,
        "paper": "Fig. 8 decode story on multi-tenant captured traffic; "
                 "continuous batching vs lockstep single-batch",
    }
    write_artifacts(
        "serve_bench",
        "rid,arrival,admitted,first_token,finished,prompt_len,gen,"
        "preemptions,latency_iters,single_batch_latency_iters",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _shared_prefix_workload(cfg, n_req: int, n_sys: int = 4,
                            sys_len: int = 24, seed: int = 0):
    """N requests over a handful of system prompts: the multi-tenant
    shape (shared system prompts / few-shot templates) whose physical
    page reuse the prefix cache exists to exploit."""
    from repro.serve.scheduler import PoissonArrivals

    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(1, cfg.vocab, size=sys_len)
                   for _ in range(n_sys)]
    arrivals = PoissonArrivals(n_req, rate=0.6, prompt_len=(2, 8),
                               gen_len=(4, 8), seed=seed)
    work = []
    for i, (t, user_len, gen) in enumerate(arrivals):
        prompt = np.concatenate([sys_prompts[i % n_sys],
                                 rng.integers(1, cfg.vocab, size=user_len)])
        work.append((t, prompt, gen))
    return work


def _run_prefix(cfg, params, workload, prefix_cache: bool):
    from repro.serve.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_len=48, max_batch=8, chunk=8,
                      nsb_pages=32, capture_trace=True,
                      prefix_cache=prefix_cache)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    wall = time.perf_counter() - t0
    return eng, wall


def prefix_bench():
    """Registered in benchmarks.run as ``prefix_bench``: the shared-prefix
    serving scenario, with vs without cross-request prefix caching.

    Reports prefill-token savings, TTFT/throughput deltas, the
    cached-page hit rate, and the captured-trace NVR replay for both
    runs — the "does the paper's NSB story hold on honest multi-tenant
    reuse?" experiment.
    """
    import jax

    from repro.configs import get_config
    from repro.core.nvr import demand_miss_reduction
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.models import api
    from repro.serve.engine import percentile

    cfg = get_config("qwen2-1.5b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(12, int(24 * SCALE))
    workload = _shared_prefix_workload(cfg, n_req)

    on, on_wall = _run_prefix(cfg, params, workload, prefix_cache=True)
    off, off_wall = _run_prefix(cfg, params, workload, prefix_cache=False)
    m_on, m_off = on.metrics(), off.metrics()
    red_on = demand_miss_reduction(on.captured_trace())
    red_off = demand_miss_reduction(off.captured_trace())

    # sanity: sharing must not change what any request generates
    for rid in off.requests:
        a, b = off.requests[rid], on.requests[rid]
        assert a.out_tokens == b.out_tokens, f"rid {rid} diverged"

    # attachable pages only: partial tail pages can never be prefix hits
    prompt_pages = sum(
        (1 + r.n_preemptions) * (r.prompt_len // cfg.kv_page)
        for r in on.requests.values())
    hit_rate = on.allocator.stats.prefix_hits / max(1, prompt_pages)

    rows = []
    for rid in sorted(on.requests):
        a, b = on.requests[rid], off.requests[rid]
        rows.append((rid, f"{a.arrival:.2f}", a.prompt_len,
                     a.cached_tokens, len(a.out_tokens),
                     f"{a.ttft():.0f}", f"{b.ttft():.0f}",
                     f"{a.latency():.0f}", f"{b.latency():.0f}"))

    ttft_on = [r.ttft() for r in on.requests.values()]
    ttft_off = [r.ttft() for r in off.requests.values()]
    headline = {
        "n_requests": float(n_req),
        "prefill_tokens_no_sharing": float(m_off["prefill_tokens_run"]),
        "prefill_tokens_shared": float(m_on["prefill_tokens_run"]),
        "prefill_token_savings_pct": 100.0 * (
            1 - m_on["prefill_tokens_run"]
            / max(1, m_off["prefill_tokens_run"])),
        "cached_page_hit_rate": hit_rate,
        "cow_copies": float(m_on["cow_copies"]),
        "p50_ttft_shared": percentile(ttft_on, 0.50),
        "p50_ttft_no_sharing": percentile(ttft_off, 0.50),
        "throughput_tok_per_iter_shared":
            m_on["tokens_out"] / m_on["iterations"],
        "throughput_tok_per_iter_no_sharing":
            m_off["tokens_out"] / m_off["iterations"],
        "throughput_tok_per_s_shared": m_on["tokens_out"] / on_wall,
        "throughput_tok_per_s_no_sharing": m_off["tokens_out"] / off_wall,
        "nsb_hot_hit_rate_shared": m_on["nsb_hot_hit_rate"],
        "nsb_hot_hit_rate_no_sharing": m_off["nsb_hot_hit_rate"],
        "nvr_miss_reduction_shared": red_on,
        "nvr_miss_reduction_no_sharing": red_off,
        "paper": "NSB reuse premise on honest multi-tenant traffic: "
                 "shared system prompts -> physical-page reuse the "
                 "16KB-NSB story depends on",
    }
    write_artifacts(
        "prefix_bench",
        "rid,arrival,prompt_len,cached_tokens,gen,ttft_shared,"
        "ttft_no_sharing,latency_shared,latency_no_sharing",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _run_tp(cfg, params, workload, mesh=None, assert_donation=False):
    """One full Poisson run through the engine at a given sharding."""
    import jax

    from repro.serve.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_len=48, max_batch=8, chunk=8,
                      nsb_pages=32, capture_trace=True, mesh=mesh)
    if assert_donation:
        # pool donation must survive sharding: the jitted step consumes
        # the input pool buffers instead of copying the sharded pools
        eng.submit(np.arange(1, 15), max_new_tokens=2)
        k0, v0, s0 = eng.k_pool, eng.v_pool, eng.s_pool
        eng.step()
        assert k0.is_deleted() and v0.is_deleted() and s0.is_deleted(), \
            f"pool buffers not donated at tp={eng.tp}"
        del eng
        jax.clear_caches()
        eng = PagedEngine(cfg, params, max_len=48, max_batch=8, chunk=8,
                          nsb_pages=32, capture_trace=True, mesh=mesh)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    wall = time.perf_counter() - t0
    return eng, wall


def tp_serve_bench():
    """Registered in benchmarks.run as ``tp_serve_bench``: the same
    Poisson serve workload through the paged engine at tp=1 vs tp=2
    (and tp=4 on a 4-KV-head config variant), with per-request token
    streams and logits asserted **bitwise-identical** across tp in the
    same run, pool donation asserted under sharding, and per-shard NSB
    hit rates reported.

    Needs forced host devices for the sharded runs
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``); tp levels
    the device count cannot host are reported as skipped, never
    silently dropped.
    """
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.nvr.capture import nsb_shard_rollup
    from repro.launch.mesh import make_serve_mesh
    from repro.models import api

    n_dev = jax.device_count()
    n_req = max(12, int(24 * SCALE))
    rows = []
    headline = {"n_requests": float(n_req), "devices": float(n_dev)}

    def bitwise(a_eng, b_eng):
        for rid in a_eng.requests:
            a, b = a_eng.requests[rid], b_eng.requests[rid]
            assert a.out_tokens == b.out_tokens, f"rid {rid} tokens"
            assert np.array_equal(a.last_logits, b.last_logits), \
                f"rid {rid} logits diverged across tp"

    # tp in {1, 2} on the stock reduced config (2 KV heads); tp=4 needs
    # 4 KV heads, so it runs on an MHA-style variant vs its own tp=1
    plans = [("qwen2-1.5b", None, (1, 2)),
             ("qwen2-1.5b", {"n_kv_heads": 4}, (1, 4))]
    for arch, patch, tps in plans:
        cfg = get_config(arch).reduced()
        label = arch
        if patch:
            cfg = dataclasses.replace(cfg, **patch)
            label = f"{arch}-kv{cfg.n_kv_heads}"
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        workload = _workload(cfg, n_req)
        baseline = None
        for tp in tps:
            if tp > n_dev:
                print(f"[tp_serve_bench] skip {label} tp={tp}: only "
                      f"{n_dev} device(s) (set XLA_FLAGS=--xla_force_"
                      "host_platform_device_count=4)")
                headline[f"tok_per_s_{label}_tp{tp}"] = float("nan")
                continue
            mesh = make_serve_mesh(tp) if tp > 1 else None
            eng, wall = _run_tp(cfg, params, workload, mesh=mesh,
                                assert_donation=tp > 1)
            m = eng.metrics()
            if baseline is None:
                baseline = eng
            else:
                bitwise(baseline, eng)
            tok_s = m["tokens_out"] / wall
            headline[f"tok_per_s_{label}_tp{tp}"] = tok_s
            shard_rates = m.get("nsb_shard_hit_rates",
                                [m["nsb_hot_hit_rate"]])
            if tp > 1:
                # offline twin: replay the shard-tagged captured stream
                # through per-shard NSB models (per-event granularity,
                # vs the engine's per-iteration unique-page accounting)
                roll = nsb_shard_rollup(eng.recorder, 32, tp)
                headline[f"nsb_replay_rollup_{label}_tp{tp}"] = \
                    roll["hit_rate"]
            rows.append((label, tp, f"{tok_s:.1f}",
                         f"{m['p50_latency']:.0f}",
                         f"{m['nsb_hot_hit_rate']:.3f}",
                         ";".join(f"{r:.3f}" for r in shard_rates),
                         f"{m['kv_pool_mib_per_shard']:.3f}",
                         m["preemptions"]))
    headline["paper"] = ("NVR as a per-NPU mechanism surviving "
                         "scale-out: KV-head-sharded pools, per-shard "
                         "NSBs, bitwise-identical decode across tp")
    from repro.core.nvr.engine.sweep import write_artifacts
    write_artifacts(
        "tp_serve_bench",
        "config,tp,tokens_per_s,p50_latency_iters,nsb_hit_rate,"
        "nsb_shard_hit_rates,kv_pool_mib_per_shard,preemptions",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _run_runahead_mode(cfg, params, workload, mode: str):
    from repro.serve.engine import PagedEngine

    # budget 16 copies/iteration: at 8 decode rows the predictors can
    # want > 8 fresh pages per step, and a starved budget (high
    # budget_truncated) caps coverage below the demand-LRU baseline
    eng = PagedEngine(cfg, params, max_len=48, max_batch=8, chunk=8,
                      nsb_pages=32, runahead=mode, runahead_pages=16)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    return eng, time.perf_counter() - t0


def runahead_bench():
    """Registered in benchmarks.run as ``runahead_bench``: the online
    vector-runahead stage on captured Poisson shared-prefix traffic.

    Three engines serve the identical workload — runahead ``off`` (the
    demand-LRU hot-set is the no-runahead NSB baseline), ``imp`` (stage
    the *current* selection: IMP's structurally one-step-behind
    prefetcher) and ``nvr`` (history + stability filter + layer-0 proxy
    address-generation slice).  Asserted in-run:

    * every request's tokens and logits are **bitwise-identical** across
      the three modes (runahead is sound by construction — staging only
      relocates byte-exact copies);
    * the demand-LRU comparator tracked inside the nvr run matches the
      off engine's hit rate exactly (same demand stream, same policy);
    * nvr's staged-tier hit rate strictly exceeds the no-runahead
      demand-LRU baseline (the paper's lift claim, online).

    Throughput is reported two ways: wall tokens/s (CPU-hosted, includes
    interpreter overheads the paper's NPU would not pay) and a modeled
    memory-stall figure from the machine model's latencies — every
    demand page access costs an NSB hit (2.0 cycles, the capture-layer
    NSB model) or a DRAM fetch (150.0 cycles unloaded) — on the
    bitwise-identical page stream, which isolates the hit-rate lift's
    bandwidth value from host noise.
    """
    import jax

    from repro.configs import get_config
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.core.nvr.machine import DRAM
    from repro.models import api

    cfg = get_config("qwen2-1.5b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(10, int(20 * SCALE))
    workload = _shared_prefix_workload(cfg, n_req)

    miss_lat = DRAM().latency          # 150.0 cycles, unloaded
    hit_lat = 2.0                      # capture.PageCache NSB hit latency

    runs = {}
    for mode in ("off", "imp", "nvr"):
        runs[mode] = _run_runahead_mode(cfg, params, workload, mode)

    base = runs["off"][0]
    for mode in ("imp", "nvr"):
        eng = runs[mode][0]
        for rid in base.requests:
            a, b = base.requests[rid], eng.requests[rid]
            assert a.out_tokens == b.out_tokens, \
                f"rid {rid} tokens diverged under runahead={mode}"
            assert np.array_equal(a.last_logits, b.last_logits), \
                f"rid {rid} logits diverged under runahead={mode}"

    m_off = base.metrics()
    rows = []
    stalls = {}
    headline = {"n_requests": float(n_req),
                "bitwise_parity_modes": "off=imp=nvr"}
    for mode, (eng, wall) in runs.items():
        m = eng.metrics()
        hits, misses = eng.stats.nsb_hits, eng.stats.nsb_misses
        stall = hits * hit_lat + misses * miss_lat
        stalls[mode] = stall
        tok_s = m["tokens_out"] / wall
        headline[f"nsb_hit_rate_{mode}"] = m["nsb_hot_hit_rate"]
        headline[f"tok_per_s_wall_{mode}"] = tok_s
        headline[f"modeled_stall_cycles_per_tok_{mode}"] = \
            stall / max(1, m["tokens_out"])
        if mode != "off":
            headline[f"runahead_accuracy_{mode}"] = m["runahead_accuracy"]
            headline[f"runahead_coverage_{mode}"] = m["runahead_coverage"]
            headline[f"runahead_overfetch_{mode}"] = m["runahead_overfetch"]
            # in-run parity: the comparator LRU inside this run saw the
            # bitwise-identical demand stream the off engine served
            assert m["nsb_demand_lru_hit_rate"] == m_off["nsb_hot_hit_rate"], \
                f"demand-LRU comparator diverged from the off run ({mode})"
        rows.append((
            mode, f"{m['nsb_hot_hit_rate']:.4f}",
            f"{m.get('nsb_demand_lru_hit_rate') or m['nsb_hot_hit_rate']:.4f}",
            "" if m.get("runahead_accuracy") is None
            else f"{m['runahead_accuracy']:.4f}",
            "" if m.get("runahead_coverage") is None
            else f"{m['runahead_coverage']:.4f}",
            "" if m.get("runahead_overfetch") is None
            else f"{m['runahead_overfetch']:.4f}",
            m.get("runahead_staged_pages", 0),
            m.get("runahead_stage_calls", 0),
            m.get("runahead_invalidations", 0),
            f"{stall / max(1, m['tokens_out']):.1f}",
            f"{tok_s:.1f}"))

    lift = (headline["nsb_hit_rate_nvr"] - headline["nsb_hit_rate_off"])
    gain = stalls["off"] / max(1e-9, stalls["nvr"])
    headline["nsb_hit_rate_lift_nvr_vs_off"] = lift
    headline["modeled_tok_throughput_gain_nvr_vs_off"] = gain
    assert lift > 0, \
        f"nvr runahead shows no NSB hit-rate lift over demand-LRU ({lift})"
    assert gain > 1.0, \
        f"nvr runahead shows no modeled throughput gain ({gain})"
    headline["paper"] = (
        "online DARE-filtered vector runahead vs IMP one-step-behind vs "
        "no-runahead NSB on live multi-tenant decode; correctness-free "
        "speculation (bitwise tokens), fuzzy-fetch over-fetch reported")
    write_artifacts(
        "runahead_bench",
        "mode,nsb_hit_rate,demand_lru_hit_rate,accuracy,coverage,"
        "overfetch,staged_pages,stage_calls,invalidations,"
        "modeled_stall_cycles_per_tok,tok_per_s_wall",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _run_spill_mode(cfg, params, workload, n_pages: int,
                    spill: int, compress: bool = False,
                    runahead: str = "off"):
    from repro.serve.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_len=48, n_pages=n_pages,
                      max_batch=8, chunk=8, nsb_pages=16,
                      runahead=runahead, runahead_pages=16,
                      spill_pages=spill, spill_compress=compress)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    wall = time.perf_counter() - t0
    eng.allocator.check_tier_invariants()
    return eng, wall


def spill_bench():
    """Registered in benchmarks.run as ``spill_bench``: swap, don't
    recompute — the host KV spill tier under pool oversubscription.

    The same Poisson workload runs through a deliberately undersized
    HBM pool (aggregate demand pages are several times ``n_pages``, so
    the scheduler *must* preempt) under four policies:

    * ``recompute`` — the historic baseline: eviction frees pages and
      resume re-prefills + replays (spill tier off);
    * ``swap`` — eviction snapshots pages to the host spill pool and
      resume restores them (no re-prefill, no replay);
    * ``swap+ra`` — swap plus the nvr runahead stage, whose fetch-back
      swap-resumes the spilled queue head in the between-steps window
      and pre-stages its history pages host->HBM->NSB;
    * ``swap-int8`` — swap with the spilled K/V planes int8-compressed
      (per-page scales via ``optim.compress``; summaries exact).

    Asserted in-run: every request's tokens and logits are
    **bitwise-identical** between recompute and the uncompressed swap
    tiers (swap restores identical content in identical logical order;
    selection and attention address pages through the block table, so
    physical renaming cannot change a logit), at least one swap-out
    actually happened (the workload genuinely oversubscribes), and
    swap's p50 resume-TTFT (re-admission to next new token) beats
    recompute's.  The int8 tier reports its measured worst-case
    dequantisation error bound instead of a bitwise claim.
    """
    import jax

    from repro.configs import get_config
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.models import api

    cfg = get_config("qwen2-1.5b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(12, int(24 * SCALE))
    workload = _workload(cfg, n_req)
    # oversubscription: every batch slot wants up to 9 pages
    # (prompt<=24 + gen<=10 at page=4) but the pool holds 12 demand
    # pages total — far below max_batch * 9 aggregate demand
    n_pages = 13
    demand_pages = sum(-(-(len(p) + g) // cfg.kv_page)
                       for _, p, g in workload)
    assert demand_pages > 2 * (n_pages - 1), \
        "workload does not oversubscribe the pool"

    runs = {
        "recompute": _run_spill_mode(cfg, params, workload, n_pages, 0),
        "swap": _run_spill_mode(cfg, params, workload, n_pages, 64),
        "swap+ra": _run_spill_mode(cfg, params, workload, n_pages, 64,
                                   runahead="nvr"),
        "swap-int8": _run_spill_mode(cfg, params, workload, n_pages, 64,
                                     compress=True),
    }

    base = runs["recompute"][0]
    for mode in ("swap", "swap+ra"):
        eng = runs[mode][0]
        for rid in base.requests:
            a, b = base.requests[rid], eng.requests[rid]
            assert a.out_tokens == b.out_tokens, \
                f"rid {rid} tokens diverged under {mode}"
            assert np.array_equal(a.last_logits, b.last_logits), \
                f"rid {rid} logits diverged under {mode}"

    rows = []
    headline = {"n_requests": float(n_req),
                "hbm_pool_pages": float(n_pages - 1),
                "workload_demand_pages": float(demand_pages),
                "bitwise_parity_modes": "recompute=swap=swap+ra"}
    for mode, (eng, wall) in runs.items():
        m = eng.metrics()
        gaps = [g for r in eng.requests.values() for g in r.resume_gaps]
        tag = mode.replace("+", "_").replace("-", "_")
        headline[f"p50_resume_ttft_{tag}"] = m["p50_resume_ttft"]
        headline[f"p99_resume_ttft_{tag}"] = m["p99_resume_ttft"]
        headline[f"p50_tpot_{tag}"] = m["p50_tpot"]
        headline[f"p99_tpot_{tag}"] = m["p99_tpot"]
        headline[f"iterations_{tag}"] = float(m["iterations"])
        headline[f"tok_per_s_wall_{tag}"] = m["tokens_out"] / wall
        rows.append((
            mode, m["preemptions"], m.get("swap_outs", 0),
            m.get("swap_ins", 0), m.get("fetch_backs", 0),
            m.get("spill_fallbacks", 0), len(gaps),
            "" if m["p50_resume_ttft"] is None
            else f"{m['p50_resume_ttft']:.0f}",
            "" if m["p99_resume_ttft"] is None
            else f"{m['p99_resume_ttft']:.0f}",
            m["iterations"], m["tokens_out"],
            f"{m['tokens_out'] / wall:.1f}",
            f"{m.get('spill_dequant_error_bound', 0.0):.3e}"))

    m_swap = runs["swap"][0].metrics()
    assert m_swap["swap_outs"] > 0, \
        "no swap-out happened: the bench is not oversubscribed"
    assert m_swap["n_resumes"] > 0, "no resume was measured"
    assert headline["p50_resume_ttft_recompute"] is not None \
        and headline["p50_resume_ttft_swap"] is not None
    imp = (headline["p50_resume_ttft_recompute"]
           / max(1e-9, headline["p50_resume_ttft_swap"]))
    headline["resume_ttft_improvement_x"] = imp
    assert imp > 1.0, \
        f"swap resume-TTFT not better than recompute ({imp:.2f}x)"
    headline["int8_dequant_error_bound"] = \
        runs["swap-int8"][0].metrics()["spill_dequant_error_bound"]
    headline["fetch_backs_swap_ra"] = \
        float(runs["swap+ra"][0].metrics()["fetch_backs"])
    headline["paper"] = (
        "off-chip latency hiding with real latency: three-level "
        "NSB/HBM/host hierarchy, preemption as swap-out, runahead "
        "fetch-back ahead of demand (DARE tolerance of irregular "
        "misses; SparCE skip-don't-recompute)")
    write_artifacts(
        "spill_bench",
        "mode,preemptions,swap_outs,swap_ins,fetch_backs,"
        "recompute_fallbacks,n_resumes,p50_resume_ttft,p99_resume_ttft,"
        "iterations,tokens_out,tok_per_s_wall,int8_err_bound",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _mixed_workload(cfg, n_req: int, seed: int = 0):
    """Long prefills interleaved with steady decoders — the load shape
    where a synchronous step loop hurts most: every long prompt's chunk
    train serialises in front of each decoding user's next token."""
    rng = np.random.default_rng(seed)
    work = []
    t = 0.0
    for i in range(n_req):
        t += float(rng.exponential(1.0 / 0.6))
        if i % 3 == 0:
            plen, gen = int(rng.integers(28, 41)), int(rng.integers(3, 6))
        else:                                    # steady decoder
            plen, gen = int(rng.integers(4, 10)), int(rng.integers(8, 14))
        work.append((t, rng.integers(1, cfg.vocab, size=plen), gen))
    return work


def _run_overlap(cfg, params, workload, executor: str):
    from repro.serve.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_len=48, max_batch=8, chunk=8,
                      nsb_pages=32, runahead="nvr", runahead_pages=8,
                      executor=executor)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    wall = time.perf_counter() - t0
    return eng, wall


def _modeled_times(iter_log, overlap: bool):
    """Cumulative modeled time after each iteration, from the shared
    iteration log ``[(n_prefill_chunks, n_decode_rows), ...]``.

    Unit cost model, deliberately wall-clock-free so the regression gate
    stays deterministic: each prefill chunk is one jit call (cost 1),
    the decode batch is one jit call (cost 1), and every iteration pays
    1 for scheduling + drains.  The synchronous loop runs the streams
    serially (1 + p + d); the pipelined executor dispatches both before
    blocking on either, so the device-side critical path is the longer
    stream (1 + max(p, d)) — the same modeled-cost pattern
    runahead_bench uses for stall cycles."""
    times, t = [], 0.0
    for n_p, n_d in iter_log:
        d = 1 if n_d else 0
        t += 1 + ((max(n_p, d)) if overlap else (n_p + d))
        times.append(t)
    return times


def overlap_bench():
    """Registered in benchmarks.run as ``overlap_bench``: the pipelined
    executor vs the synchronous step loop under mixed load.

    A mixed long-prefill/steady-decode Poisson workload runs through
    both executors (runahead=nvr, no spill — so the schedules are
    provably identical and the comparison is purely about overlap).
    Asserted in-run: every request's tokens and logits are
    **bitwise-identical** between executors, and the two engines walked
    the *same* iteration log.  Headlines split latency per stream: TTFT
    (prefill stream) and TPOT (decode stream) percentiles in scheduler
    ticks, plus modeled-time TPOT under the unit cost model — sync pays
    prefill chunks + decode serially per iteration, async pays their
    max — where the p99 TPOT win under mixed load is the number the
    refactor exists for.
    """
    import jax

    from repro.configs import get_config
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.models import api
    from repro.serve.engine import percentile

    cfg = get_config("qwen2-1.5b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(12, int(24 * SCALE))
    workload = _mixed_workload(cfg, n_req, seed=23)

    sync_eng, sync_wall = _run_overlap(cfg, params, workload, "sync")
    pipe_eng, pipe_wall = _run_overlap(cfg, params, workload, "async")

    # the standing invariant, asserted in-run: bitwise tokens + logits
    for rid in sync_eng.requests:
        a, b = sync_eng.requests[rid], pipe_eng.requests[rid]
        assert a.out_tokens == b.out_tokens, f"rid {rid} tokens diverged"
        assert np.array_equal(a.last_logits, b.last_logits), \
            f"rid {rid} logits diverged"
    # no spill tier -> no sanctioned divergence: same iteration log
    assert sync_eng.stats.iter_log == pipe_eng.stats.iter_log, \
        "executors walked different schedules on a no-spill config"

    iter_log = pipe_eng.stats.iter_log
    t_sync = _modeled_times(iter_log, overlap=False)
    t_async = _modeled_times(iter_log, overlap=True)

    def modeled_stream_stats(times):
        # map each request's token ticks (iteration numbers) through the
        # cumulative modeled clock; arrival maps to the end of the last
        # iteration that closed before it
        def at(tick):
            i = min(len(times) - 1, max(0, int(tick) - 1))
            return times[i] if tick >= 1 else 0.0
        ttfts, tpots = [], []
        for r in pipe_eng.requests.values():
            if r.first_token_at >= 0:
                ttfts.append(at(r.first_token_at) - at(r.arrival))
            if len(r.token_ticks) >= 2:
                tpots.append((at(r.token_ticks[-1])
                              - at(r.token_ticks[0]))
                             / (len(r.token_ticks) - 1))
        return ttfts, tpots

    ttft_s, tpot_s = modeled_stream_stats(t_sync)
    ttft_a, tpot_a = modeled_stream_stats(t_async)
    m = pipe_eng.metrics()
    ms = sync_eng.metrics()

    headline = {
        "n_requests": float(n_req),
        "bitwise_parity": 1.0,              # asserted above, in-run
        # per-stream latency in scheduler ticks (identical schedules ->
        # identical tick metrics; the split itself is the satellite)
        "p50_ttft_iters": m["p50_ttft"],
        "p99_ttft_iters": m["p99_ttft"],
        "p50_tpot_iters": m["p50_tpot"],
        "p99_tpot_iters": m["p99_tpot"],
        # modeled-time stream latencies under the unit cost model — the
        # deterministic overlap win the gate watches
        "p99_ttft_modeled_sync": percentile(ttft_s, 0.99),
        "p99_ttft_modeled_async": percentile(ttft_a, 0.99),
        "p50_tpot_modeled_sync": percentile(tpot_s, 0.50),
        "p50_tpot_modeled_async": percentile(tpot_a, 0.50),
        "p99_tpot_modeled_sync": percentile(tpot_s, 0.99),
        "p99_tpot_modeled_async": percentile(tpot_a, 0.99),
        "overlap_fraction": m["overlap_fraction"],
        "prefill_iterations": float(m["prefill_iterations"]),
        "decode_iterations": float(m["decode_iterations"]),
        "plan_reuse_fraction": m["plan_reuse_fraction"],
        "plan_repairs": float(m["plan_repairs"]),
        "tok_per_s_wall_sync": ms["tokens_out"] / sync_wall,
        "tok_per_s_wall_async": m["tokens_out"] / pipe_wall,
    }
    imp = (headline["p99_tpot_modeled_sync"]
           / max(1e-9, headline["p99_tpot_modeled_async"]))
    headline["tpot_p99_improvement_x"] = imp
    assert imp > 1.0, \
        f"overlap did not improve modeled p99 TPOT ({imp:.2f}x)"
    headline["paper"] = (
        "runahead as a decoupled sub-thread concurrent with NPU "
        "execution: disaggregated prefill/decode streams with the "
        "stage and spill transfers under the overlap window "
        "(NeutronSparse's coordinated heterogeneous engines)")

    rows = []
    for rid in sorted(pipe_eng.requests):
        r = pipe_eng.requests[rid]
        tp = r.tpot()
        rows.append((rid, f"{r.arrival:.2f}", r.prompt_len,
                     len(r.out_tokens),
                     "" if r.ttft() is None else f"{r.ttft():.0f}",
                     "" if tp is None else f"{tp:.2f}"))
    write_artifacts(
        "overlap_bench",
        "rid,arrival,prompt_len,gen,ttft_iters,tpot_iters",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _run_moe_mode(cfg, params, workload, mode: str, mesh=None,
                  slots: int = 24):
    from repro.serve.engine import PagedEngine

    kw = {"expert_pool": "dense" if mode == "dense" else "paged"}
    if mode == "paged+router":
        kw.update(expert_runahead="router", expert_nsb_slots=slots,
                  expert_runahead_pages=slots)
    n_logical = 48 // cfg.kv_page
    eng = PagedEngine(cfg, params, max_len=48,
                      n_pages=1 + 2 * n_logical,   # << max_batch full-size:
                      max_batch=8, chunk=8,        # preemption pressure
                      capture_trace=True, mesh=mesh, **kw)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    return eng, time.perf_counter() - t0


def moe_serve_bench():
    """Registered in benchmarks.run as ``moe_serve_bench``: paged
    expert-weight streaming with router-keyed runahead on a live MoE
    serve load.

    Three engines serve the identical Poisson workload on the reduced
    ``qwen3-moe-235b-a22b`` config with an undersized KV pool (so the
    scheduler preempts — asserted in-run): expert_pool ``dense``
    (dense-materialised per-layer expert rows, the baseline gather),
    ``paged`` (expert tiles resolved through block tables in the
    physical page pool; its expert-tile hit accounting *is* the
    demand-LRU baseline) and ``paged+router`` (router-keyed runahead
    staging predicted tiles into the pool's NSB tail).  Asserted
    in-run:

    * every request's tokens and logits are **bitwise-identical**
      across dense / paged / paged+router — the gathers differ, the
      math does not (expert tiles are read-only; staged copies are
      byte-exact and never stale);
    * with >= 2 host devices, a tp=2 ``paged+router`` engine (sharded
      QKV + KV pools, replicated router/expert weights) reproduces the
      tp=1 tokens and logits bitwise;
    * the demand-LRU comparator inside the router run matches the
      paged run's hit rate exactly (same demand page stream);
    * the router-keyed tier's expert-tile NSB hit rate strictly
      exceeds that demand-LRU baseline — the paper's lift claim on the
      one workload its runahead thread was designed around.

    Throughput is reported as wall tokens/s plus a modeled
    memory-stall figure from the machine model's latencies (expert
    tile fetch: NSB hit 2.0 cycles vs DRAM miss 150.0) on the
    bitwise-identical expert page stream.
    """
    import jax

    from repro.configs import get_config
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.core.nvr.machine import DRAM
    from repro.models import api

    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(8, int(16 * SCALE))
    workload = _workload(cfg, n_req, seed=11)

    miss_lat = DRAM().latency          # 150.0 cycles, unloaded
    hit_lat = 2.0                      # capture.PageCache NSB hit latency

    runs = {}
    for mode in ("dense", "paged", "paged+router"):
        runs[mode] = _run_moe_mode(cfg, params, workload, mode)

    base = runs["dense"][0]
    assert base.stats.preemptions > 0, \
        "workload did not preempt: the bench must cover eviction paths"
    for mode in ("paged", "paged+router"):
        eng = runs[mode][0]
        for rid in base.requests:
            a, b = base.requests[rid], eng.requests[rid]
            assert a.out_tokens == b.out_tokens, \
                f"rid {rid} tokens diverged under expert_pool={mode}"
            assert np.array_equal(a.last_logits, b.last_logits), \
                f"rid {rid} logits diverged under expert_pool={mode}"

    headline = {"n_requests": float(n_req),
                "preemptions": float(base.stats.preemptions),
                "bitwise_parity_modes": "dense=paged=paged+router"}

    # tp=2 leg: replicated expert weights under a sharded serve mesh
    import jax as _jax
    if _jax.device_count() >= 2:
        from repro.launch.mesh import make_serve_mesh
        tp_eng, _ = _run_moe_mode(cfg, params, workload, "paged+router",
                                  mesh=make_serve_mesh(2))
        ra = runs["paged+router"][0]
        for rid in ra.requests:
            a, b = ra.requests[rid], tp_eng.requests[rid]
            assert a.out_tokens == b.out_tokens, \
                f"rid {rid} tokens diverged at tp=2"
            assert np.array_equal(a.last_logits, b.last_logits), \
                f"rid {rid} logits diverged at tp=2"
        headline["tp2_bitwise_parity"] = 1.0
    else:
        headline["tp2_bitwise_parity"] = float("nan")   # skipped

    m_paged = runs["paged"][0].metrics()
    rows = []
    stalls = {}
    for mode, (eng, wall) in runs.items():
        m = eng.metrics()
        hits = eng.stats.expert_nsb_hits
        misses = eng.stats.expert_nsb_misses
        stall = hits * hit_lat + misses * miss_lat
        stalls[mode] = stall
        tok_s = m["tokens_out"] / wall
        key = mode.replace("+", "_")
        headline[f"expert_nsb_hit_rate_{key}"] = m["expert_nsb_hit_rate"]
        headline[f"modeled_stall_cycles_per_tok_{key}"] = \
            stall / max(1, m["tokens_out"])
        headline[f"tok_per_s_wall_{key}"] = tok_s
        if mode == "paged+router":
            headline["expert_runahead_accuracy"] = \
                m["expert_runahead_accuracy"]
            headline["expert_runahead_coverage"] = \
                m["expert_runahead_coverage"]
            headline["expert_runahead_overfetch"] = \
                m["expert_runahead_overfetch"]
            # in-run comparator parity: the demand-LRU twin inside this
            # run saw the bitwise-identical expert page stream the
            # plain paged engine served
            assert (m["expert_demand_lru_hit_rate"]
                    == m_paged["expert_nsb_hit_rate"]), \
                "expert demand-LRU comparator diverged from the paged run"
        rows.append((
            mode,
            "" if m["expert_nsb_hit_rate"] is None
            else f"{m['expert_nsb_hit_rate']:.4f}",
            "" if m.get("expert_demand_lru_hit_rate") is None
            else f"{m['expert_demand_lru_hit_rate']:.4f}",
            "" if m.get("expert_runahead_accuracy") is None
            else f"{m['expert_runahead_accuracy']:.4f}",
            m["expert_pages_touched"],
            m.get("expert_staged_pages", 0),
            m.get("expert_stage_calls", 0),
            f"{stall / max(1, m['tokens_out']):.1f}",
            f"{tok_s:.1f}"))

    lift = (headline["expert_nsb_hit_rate_paged_router"]
            - headline["expert_nsb_hit_rate_paged"])
    gain = stalls["paged"] / max(1e-9, stalls["paged+router"])
    headline["expert_hit_rate_lift_router_vs_lru"] = lift
    headline["modeled_tok_throughput_gain_router_vs_lru"] = gain
    assert lift > 0, \
        f"router runahead shows no expert-tile hit-rate lift ({lift})"
    assert gain > 1.0, \
        f"router runahead shows no modeled stall gain ({gain})"
    ep = runs["paged"][0].ep
    headline["expert_pool_pages"] = float(ep.n_pages)
    headline["expert_pool_mib"] = ep.pool_bytes / 2 ** 20
    headline["paper"] = (
        "expert weight tiles as first-class pages with router logits as "
        "the runahead address stream: the MoE gather workload the "
        "paper's vector runahead targets, served online with "
        "correctness-free speculation (bitwise tokens dense=paged="
        "paged+router)")
    write_artifacts(
        "moe_serve_bench",
        "mode,expert_nsb_hit_rate,demand_lru_hit_rate,accuracy,"
        "pages_touched,staged_pages,stage_calls,"
        "modeled_stall_cycles_per_tok,tok_per_s_wall",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def _bursty_items(cfg, n_req: int, seed: int = 7):
    """The canonical bursty multi-tenant multi-turn trace, materialised
    (deterministic: same spec + seed + vocab => identical arrays)."""
    from repro.serve.workload import (bursty_multiturn,
                                      bursty_multiturn_tenants,
                                      materialize, shared_prefix_map)

    specs = bursty_multiturn(n_req, seed=seed)
    items = materialize(specs, cfg.vocab, seed=seed,
                        shared_prefix=shared_prefix_map(
                            bursty_multiturn_tenants()))
    longest = max(s.total_len() for s in specs)
    return items, longest


def _run_workload_policy(cfg, params, items, policy, n_pages: int,
                         spill: int, idle_swap: bool, max_len: int):
    from repro.serve.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_len=max_len, n_pages=n_pages,
                      max_batch=6, chunk=8, nsb_pages=32,
                      runahead="nvr", runahead_pages=16,
                      spill_pages=spill, policy=policy,
                      session_hold=True, idle_swap=idle_swap)
    t0 = time.perf_counter()
    eng.run(items)
    return eng, time.perf_counter() - t0


def _keyed_outputs(eng):
    """(item_index, turn) -> (tokens, logits): a rid-independent key.

    Rids diverge across policies (turn-N submissions interleave at
    different times, and session holders consume rids), but turn-1
    submission order is the arrival order of the trace — identical for
    every engine — so the rank of a request's rid among turn-1 requests
    recovers its trace index, and follow-up turns map through their
    session id."""
    t1 = sorted((r for r in eng.requests.values() if r.turn == 1),
                key=lambda r: r.rid)
    idx_of = {r.rid: i for i, r in enumerate(t1)}
    sid_of = {r.session: idx_of[r.rid] for r in t1 if r.session >= 0}
    out = {}
    for r in eng.requests.values():
        idx = idx_of[r.rid] if r.turn == 1 else sid_of[r.session]
        out[(idx, r.turn)] = (r.out_tokens, r.last_logits, r)
    return out


def workload_bench():
    """Registered in benchmarks.run as ``workload_bench``: the policy
    layer under the realistic front-door workload.

    One bursty multi-tenant multi-turn trace (``serve/workload.py``'s
    ``bursty_multiturn`` preset: MMPP arrivals, lognormal/Zipf lengths,
    per-tenant shared system prompts, TTFT/TPOT SLOs, think-time
    follow-up turns) is served three times:

    * **fifo** — strict arrival order under real pool pressure, with
      session KV held between turns and parked in the host spill tier
      during think time (``idle_swap``);
    * **slo_fair** — the same engine, same pressure, but per-tenant
      deficit-round-robin admission and SLO-aware eviction;
    * **base** — FIFO with a worst-case-sized pool and no idle swap:
      the never-preempted, never-swapped oracle.

    Asserted in-run:

    * every (trace item, turn) pair's tokens **and logits** are
      bitwise-identical across all three runs — scheduling policy,
      preemption, idle-session swap-out and cross-turn COW prefix reuse
      are all correctness-free;
    * ``slo_fair`` strictly beats ``fifo`` on aggregate SLO attainment
      **and** on p99 TTFT over the SLO-carrying tenants (the batch
      tenant's burst waves head-of-line block chat under FIFO);
    * the session layer actually exercised: follow-up turns submitted,
      idle swap-outs happened, cross-turn prefix pages were reused.

    The NSB/runahead hit rate is re-measured under this realistic
    locality (bursts + shared tenant prefixes + conversation history)
    and reported against the in-run demand-LRU comparator.
    """
    import jax

    from repro.configs import get_config
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.models import api

    cfg = get_config("qwen2-1.5b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(24, int(48 * SCALE))
    items, longest = _bursty_items(cfg, n_req)
    pg = cfg.kv_page
    max_len = -(-longest // pg) * pg
    n_logical = max_len // pg
    # pool sized so the longest conversation fits alone but concurrent
    # admissions contend: preemption + policy eviction are live
    n_pages = 1 + (7 * n_logical) // 4
    spill = 4 * n_logical

    runs = {}
    for policy, pages, sp, idle in (
            ("fifo", n_pages, spill, True),
            ("slo_fair", n_pages, spill, True),
            ("base", 0, 0, False)):
        items_run, _ = _bursty_items(cfg, n_req)
        runs[policy] = _run_workload_policy(
            cfg, params, items_run, "fifo" if policy == "base" else policy,
            pages, sp, idle, max_len)

    keyed = {name: _keyed_outputs(eng) for name, (eng, _) in runs.items()}
    base = keyed["base"]
    for name in ("fifo", "slo_fair"):
        assert keyed[name].keys() == base.keys(), \
            f"{name} served a different turn set than base"
        for key, (toks, logits, _) in keyed[name].items():
            b_toks, b_logits, _ = base[key]
            assert toks == b_toks, \
                f"{key} tokens diverged under {name} (vs never-swapped)"
            assert np.array_equal(logits, b_logits), \
                f"{key} logits diverged under {name} (vs never-swapped)"

    mf = runs["fifo"][0].metrics()
    ms = runs["slo_fair"][0].metrics()

    def _p99_ttft_slo(eng):
        """p99 TTFT over the SLO-carrying (interactive) requests — the
        tail the policy is paid to protect.  The no-deadline batch
        tenant's tail legitimately grows under slo_fair (its long
        prompts yield to chat); overall p99 is reported, not gated."""
        from repro.serve.engine import percentile
        tt = [x for x in (r.ttft() for r in eng.requests.values()
                          if r.slo_ttft is not None) if x is not None]
        return percentile(tt, 0.99)

    p99f = _p99_ttft_slo(runs["fifo"][0])
    p99s = _p99_ttft_slo(runs["slo_fair"][0])
    assert mf["preemptions"] > 0 or ms["preemptions"] > 0, \
        "no pool pressure — workload_bench is not exercising eviction"
    assert ms["turns_submitted"] > 0 and ms["idle_swap_outs"] > 0, \
        "session layer idle: no follow-up turns or idle swap-outs"
    assert ms["prefill_tokens_skipped"] > 0, \
        "no cross-turn/cross-tenant prefix reuse under the trace"
    assert ms["slo_attainment"] > mf["slo_attainment"], (
        f"slo_fair does not improve SLO attainment "
        f"({ms['slo_attainment']} vs fifo {mf['slo_attainment']})")
    assert p99s < p99f, (
        f"slo_fair does not improve p99 TTFT on the SLO tenants "
        f"({p99s} vs fifo {p99f})")

    rows = []
    for name in ("fifo", "slo_fair"):
        for (idx, turn), (_, _, r) in sorted(keyed[name].items()):
            rows.append((
                name, idx, turn, r.tenant, r.priority,
                f"{r.arrival:.2f}", f"{r.admitted_at:.0f}",
                f"{r.first_token_at:.0f}", f"{r.finished_at:.0f}",
                r.n_preemptions,
                "" if r.slo_attained() is None
                else int(r.slo_attained())))

    headline = {
        "n_requests": float(n_req),
        "n_turns_total": float(len(base)),
        "multiturn_bitwise_parity": 1.0,   # asserted above
        "slo_attainment_fifo": mf["slo_attainment"],
        "slo_attainment_slo_fair": ms["slo_attainment"],
        "slo_attainment_gain": (ms["slo_attainment"]
                                - mf["slo_attainment"]),
        "p99_ttft_slo_tenants_fifo": p99f,
        "p99_ttft_slo_tenants_slo_fair": p99s,
        "p99_ttft_all_fifo": mf["p99_ttft"],
        "p99_ttft_all_slo_fair": ms["p99_ttft"],
        "p50_ttft_fifo": mf["p50_ttft"],
        "p50_ttft_slo_fair": ms["p50_ttft"],
        "preemptions_fifo": float(mf["preemptions"]),
        "preemptions_slo_fair": float(ms["preemptions"]),
        "turns_submitted": float(ms["turns_submitted"]),
        "session_holds": float(ms["session_holds"]),
        "idle_swap_outs": float(ms["idle_swap_outs"]),
        "idle_swap_ins": float(ms["idle_swap_ins"]),
        "idle_evictions": float(ms["idle_evictions"]),
        "prefill_tokens_skipped": float(ms["prefill_tokens_skipped"]),
        "nsb_hit_rate_realistic": ms["nsb_hot_hit_rate"],
        "nsb_demand_lru_hit_rate": ms["nsb_demand_lru_hit_rate"],
        "paper": "the serving front door under production shape: bursty "
                 "multi-tenant multi-turn load through the policy layer "
                 "— SLO-fair scheduling beats FIFO with tokens bitwise-"
                 "unchanged, and the NSB/runahead lift re-measured under "
                 "realistic locality",
    }
    write_artifacts(
        "workload_bench",
        "policy,item,turn,tenant,priority,arrival,admitted,first_token,"
        "finished,preemptions,slo_attained",
        rows, results_dir(), scale=SCALE)
    return rows, headline


def main() -> None:
    for name, fn in (("serve_bench", serve_bench),
                     ("prefix_bench", prefix_bench),
                     ("runahead_bench", runahead_bench),
                     ("spill_bench", spill_bench),
                     ("overlap_bench", overlap_bench),
                     ("moe_serve_bench", moe_serve_bench),
                     ("tp_serve_bench", tp_serve_bench),
                     ("workload_bench", workload_bench)):
        rows, headline = fn()
        print(f"{name}: {len(rows)} requests")
        for k, v in headline.items():
            print(f"    {k:34s} {v:.4g}" if isinstance(v, float)
                  else f"    {k:34s} {v}")


if __name__ == "__main__":
    main()
