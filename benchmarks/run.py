"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark) plus the
per-figure headline metrics vs the paper's claims.  Detailed per-row
artifacts (paired CSV + JSON, via the engine sweep runner's writer) land
in benchmarks/results/.

Every completed benchmark additionally writes a committed-format
perf-trajectory artifact ``benchmarks/results/BENCH_<name>.json``:
the headline metrics (non-finite values nulled, keys sorted), the
BENCH_SCALE it ran at, the git sha and the harness wall time — one
stable file per bench that CI uploads and successive commits can diff.

Beyond the paper figures, eleven engineering benches ride along:
  engine_speedup    — full Fig. 5 sweep, event-driven engine vs the frozen
                      seed loop, with bit-exact parity asserted per row
  sweep_grid        — workload x dtype x prefetcher x nsb_kb grid through
                      the sweep runner (CSV + JSON artifacts)
  capture_roundtrip — replay *captured* serving/MoE traffic through the
                      simulator (needs jax; all paper figs are numpy-only)
  serve_bench       — continuous-batching Poisson load vs the single-batch
                      baseline, with multi-tenant capture -> NVR replay
  prefix_bench      — shared-system-prompt load with vs without the COW
                      prefix cache: prefill savings, TTFT, NVR replay
  paged_kernel_bench — the donated + bucketed paged-decode step loop vs
                      the pre-PR path (pool-copy / padded-row
                      elimination), with Pallas paged-kernel parity
                      asserted against the XLA oracle in the same run
  runahead_bench    — online vector runahead off/imp/nvr on shared-prefix
                      Poisson serving: bitwise parity across modes, NSB
                      hit-rate lift + modeled stall gain asserted in-run
  spill_bench       — host KV spill tier under pool oversubscription:
                      preemption as swap-out vs free-and-recompute (+
                      runahead fetch-back, int8 spill), bitwise parity
                      and resume-TTFT improvement asserted in-run
  overlap_bench     — pipelined executor vs the synchronous step loop
                      under mixed long-prefill/steady-decode load:
                      bitwise parity + identical iteration log asserted
                      in-run, TTFT/TPOT split per stream, modeled p99
                      TPOT improvement from stream overlap
  moe_serve_bench   — paged expert-weight streaming on a live MoE serve
                      load: expert tiles as pages, router-keyed runahead
                      staging into the NSB tail — bitwise parity
                      dense=paged=paged+router (and tp=2) asserted
                      in-run, expert-tile hit-rate lift over demand-LRU
  workload_bench    — the scheduling-policy layer on a bursty
                      multi-tenant multi-turn trace: slo_fair beats
                      fifo on SLO attainment + SLO-tenant p99 TTFT,
                      per-(item, turn) tokens/logits bitwise-identical
                      to a never-swapped run (idle-session swap + COW
                      cross-turn reuse are correctness-free), NSB hit
                      rate re-measured under realistic locality

CI gates the deterministic headline metrics against committed baselines
(benchmarks/check_regressions.py; see benchmarks/README.md).

Exit status: 0 only if every requested benchmark ran clean; a benchmark
that raises is reported (traceback + summary line) and the process exits
1 after the remaining benchmarks finish, so CI smoke jobs fail loudly
instead of swallowing a broken figure.  Unknown names exit 2.

  PYTHONPATH=src python -m benchmarks.run            # all figures
  BENCH_SCALE=1.0 PYTHONPATH=src python -m benchmarks.run fig5_latency
  PYTHONPATH=src python -m benchmarks.run engine_speedup serve_bench
"""

from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import time
import traceback

from .paths import results_dir


def _jsonable(v):
    """Strict-JSON view of a headline value: non-finite numbers become
    null (the committed artifact must diff cleanly and parse under
    ``allow_nan=False``), numpy scalars collapse to Python numbers,
    anything opaque falls back to ``str``."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if v is None or isinstance(v, (bool, str, int)):
        return v
    try:
        f = float(v)
    except (TypeError, ValueError):
        return str(v)
    return f if math.isfinite(f) else None


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "-C", os.path.dirname(os.path.abspath(__file__)),
             "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def _write_bench_json(name: str, headline: dict, us: float,
                      sha: str) -> str:
    """Perf-trajectory artifact: ``BENCH_<name>.json`` in the committed
    format (sorted keys, no NaNs) so successive runs diff cleanly."""
    path = os.path.join(results_dir(), f"BENCH_{name}.json")
    payload = {
        "bench": name,
        "bench_scale": float(os.environ.get("BENCH_SCALE", "0.5")),
        "git_sha": sha,
        "harness_us": round(us, 1),
        "headline": _jsonable(headline),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True, allow_nan=False)
        f.write("\n")
    return path


def main(argv=None) -> int:
    from . import paper_figs
    names = list(argv if argv is not None else sys.argv[1:]) \
        or list(paper_figs.ALL)
    unknown = [n for n in names if n not in paper_figs.ALL]
    if unknown:
        print(f"unknown benchmark(s): {', '.join(unknown)}",
              file=sys.stderr)
        print(f"available: {', '.join(paper_figs.ALL)}", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    summaries = []
    failures = []
    sha = _git_sha()
    for name in names:
        fn = paper_figs.ALL[name]
        t0 = time.perf_counter()
        try:
            rows, headline = fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"{name},FAILED,")
            continue
        us = (time.perf_counter() - t0) * 1e6
        derived = ";".join(f"{k}={v:.4g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in headline.items()
                           if k != "paper")
        print(f"{name},{us:.0f},{derived}")
        _write_bench_json(name, headline, us, sha)
        summaries.append((name, headline))
    print("\n=== headline metrics vs paper claims ===")
    for name, h in summaries:
        print(f"[{name}]")
        for k, v in h.items():
            if k == "paper":
                print(f"    paper claim : {v}")
            else:
                print(f"    {k:38s} {v:.4g}" if isinstance(v, float)
                      else f"    {k:38s} {v}")
    if failures:
        print(f"\nFAILED: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
