"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark) plus the
per-figure headline metrics vs the paper's claims.  Detailed per-row
artifacts (paired CSV + JSON, via the engine sweep runner's writer) land
in benchmarks/results/.

Beyond the paper figures, six engineering benches ride along:
  engine_speedup    — full Fig. 5 sweep, event-driven engine vs the frozen
                      seed loop, with bit-exact parity asserted per row
  sweep_grid        — workload x dtype x prefetcher x nsb_kb grid through
                      the sweep runner (CSV + JSON artifacts)
  capture_roundtrip — replay *captured* serving/MoE traffic through the
                      simulator (needs jax; all paper figs are numpy-only)
  serve_bench       — continuous-batching Poisson load vs the single-batch
                      baseline, with multi-tenant capture -> NVR replay
  prefix_bench      — shared-system-prompt load with vs without the COW
                      prefix cache: prefill savings, TTFT, NVR replay
  paged_kernel_bench — the donated + bucketed paged-decode step loop vs
                      the pre-PR path (pool-copy / padded-row
                      elimination), with Pallas paged-kernel parity
                      asserted against the XLA oracle in the same run

Exit status: 0 only if every requested benchmark ran clean; a benchmark
that raises is reported (traceback + summary line) and the process exits
1 after the remaining benchmarks finish, so CI smoke jobs fail loudly
instead of swallowing a broken figure.  Unknown names exit 2.

  PYTHONPATH=src python -m benchmarks.run            # all figures
  BENCH_SCALE=1.0 PYTHONPATH=src python -m benchmarks.run fig5_latency
  PYTHONPATH=src python -m benchmarks.run engine_speedup serve_bench
"""

from __future__ import annotations

import sys
import time
import traceback


def main(argv=None) -> int:
    from . import paper_figs
    names = list(argv if argv is not None else sys.argv[1:]) \
        or list(paper_figs.ALL)
    unknown = [n for n in names if n not in paper_figs.ALL]
    if unknown:
        print(f"unknown benchmark(s): {', '.join(unknown)}",
              file=sys.stderr)
        print(f"available: {', '.join(paper_figs.ALL)}", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    summaries = []
    failures = []
    for name in names:
        fn = paper_figs.ALL[name]
        t0 = time.perf_counter()
        try:
            rows, headline = fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"{name},FAILED,")
            continue
        us = (time.perf_counter() - t0) * 1e6
        derived = ";".join(f"{k}={v:.4g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in headline.items()
                           if k != "paper")
        print(f"{name},{us:.0f},{derived}")
        summaries.append((name, headline))
    print("\n=== headline metrics vs paper claims ===")
    for name, h in summaries:
        print(f"[{name}]")
        for k, v in h.items():
            if k == "paper":
                print(f"    paper claim : {v}")
            else:
                print(f"    {k:38s} {v:.4g}" if isinstance(v, float)
                      else f"    {k:38s} {v}")
    if failures:
        print(f"\nFAILED: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
