"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark) plus the
per-figure headline metrics vs the paper's claims.  Detailed per-row CSVs
are written to benchmarks/results/.

  PYTHONPATH=src python -m benchmarks.run            # all figures
  BENCH_SCALE=1.0 PYTHONPATH=src python -m benchmarks.run fig5_latency
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import paper_figs
    names = sys.argv[1:] or list(paper_figs.ALL)
    print("name,us_per_call,derived")
    summaries = []
    for name in names:
        fn = paper_figs.ALL[name]
        t0 = time.perf_counter()
        rows, headline = fn()
        us = (time.perf_counter() - t0) * 1e6
        derived = ";".join(f"{k}={v:.4g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in headline.items()
                           if k != "paper")
        print(f"{name},{us:.0f},{derived}")
        summaries.append((name, headline))
    print("\n=== headline metrics vs paper claims ===")
    for name, h in summaries:
        print(f"[{name}]")
        for k, v in h.items():
            if k == "paper":
                print(f"    paper claim : {v}")
            else:
                print(f"    {k:38s} {v:.4g}" if isinstance(v, float)
                      else f"    {k:38s} {v}")


if __name__ == "__main__":
    main()
