"""Paged-decode fast-path benchmark: the donated + bucketed step loop
(and the Pallas paged kernel) vs the pre-PR serve hot path.

Two measurements on the same Poisson serve workload:

1. **Step-loop speedup** — ``PagedEngine`` with pool-buffer donation and
   power-of-two row bucketing (the defaults) vs the pre-PR configuration
   (no donation: every jitted call round-trips a full ``[L,P,page,KV,D]``
   pool copy; no bucketing: every ragged decode batch pads to
   ``max_batch``).  On this CPU container the win is dominated by the
   pool-copy and padded-row eliminations — the same levers, scaled up,
   that dominate at production pool sizes.

2. **Kernel parity + micro-timing** — one decode-attention call on the
   post-run's real pool state through both implementations:
   ``attend_pages_paged`` (XLA oracle) and ``kernels.paged_decode_attn``
   (Pallas, interpret mode here; the TPU lowering is exercised
   structurally).  Parity is asserted in the same run; interpret-mode
   wall time is a Python-loop number, reported for completeness, not a
   hardware claim.

  PYTHONPATH=src python -m benchmarks.paged_kernel_bench
  PYTHONPATH=src python -m benchmarks.run paged_kernel_bench
"""

from __future__ import annotations

import os
import time

import numpy as np

SCALE = float(os.environ.get("BENCH_SCALE", "0.5"))
from .paths import results_dir


def _workload(cfg, n_req: int, seed: int = 0):
    from repro.serve.scheduler import PoissonArrivals

    rng = np.random.default_rng(seed)
    arrivals = PoissonArrivals(n_req, rate=0.5, prompt_len=(8, 24),
                               gen_len=(6, 12), seed=seed)
    return [(t, rng.integers(1, cfg.vocab, size=p), g)
            for t, p, g in arrivals]


def _warm(eng) -> None:
    """Pre-trace every decode bucket and the prefill chunk so the timed
    run measures the steady-state serving loop, not XLA compiles.  The
    warmup rows carry all-NULL block tables, so they only scribble the
    reserved scratch page 0 (same contract as real padded rows)."""
    import jax.numpy as jnp

    buckets = eng.row_buckets or (eng.max_batch,)
    for rb in buckets:
        token = jnp.zeros((rb,), jnp.int32)
        pos = jnp.zeros((rb,), jnp.int32)
        bts = jnp.zeros((rb, eng.n_logical), jnp.int32)
        _, eng.k_pool, eng.v_pool, eng.s_pool, _ = eng._decode(
            eng.params, eng.k_pool, eng.v_pool, eng.s_pool, token, pos,
            bts)
    toks = jnp.zeros((eng.chunk,), jnp.int32)
    bt = jnp.zeros((eng.n_logical,), jnp.int32)
    _, eng.k_pool, eng.v_pool, eng.s_pool = eng._prefill(
        eng.params, eng.k_pool, eng.v_pool, eng.s_pool, toks,
        np.int32(0), np.int32(1), bt)


def _run_engine(cfg, params, workload, **kw):
    from repro.serve.engine import PagedEngine

    # max_batch 16 with modest Poisson concurrency: the pre-PR pad-to-max
    # path computes mostly NULL rows, the bucketed path does not — and
    # the larger pool makes the undonated per-call copy an honest cost
    eng = PagedEngine(cfg, params, max_len=384, max_batch=16, chunk=16,
                      nsb_pages=32, **kw)
    _warm(eng)
    t0 = time.perf_counter()
    eng.run([(t, p.copy(), g) for t, p, g in workload])
    wall = time.perf_counter() - t0
    return eng, wall


def _kernel_parity_and_timing(cfg, eng, n_timing: int = 20):
    """One decode-attention call on the run's real layer-0 pool state,
    both implementations; returns (max_abs_err, us_xla, us_pallas)."""
    import jax
    import jax.numpy as jnp

    from repro.models import sparse_attention

    rng = np.random.default_rng(3)
    r, kv, g = 8, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    nl = eng.n_logical
    page = eng.page
    q = jnp.asarray(rng.normal(size=(r, kv, g, cfg.hd)), jnp.float32)
    bt = np.zeros((r, nl), np.int32)
    for i in range(r):
        bt[i] = rng.choice(np.arange(1, eng.n_pages), size=nl,
                           replace=False)
    pos = jnp.asarray(rng.integers(page, nl * page, size=r), jnp.int32)
    n_valid = pos // page + 1
    k_sel = int(min(cfg.kv_topk_pages, nl))
    idx, phys = sparse_attention.select_pages_blocktable(
        q, eng.s_pool[0], jnp.asarray(bt), n_valid, k_sel)

    xla = jax.jit(lambda *a: sparse_attention.attend_pages_paged(*a, page))
    pal = lambda *a: sparse_attention.attend_pages_paged_kernel(*a, page)
    args = (q, eng.k_pool[0], eng.v_pool[0], idx, phys, pos)
    want = jax.block_until_ready(xla(*args))
    got = jax.block_until_ready(pal(*args))
    err = float(np.abs(np.asarray(got, np.float32)
                       - np.asarray(want, np.float32)).max())

    def timeit(fn):
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(n_timing):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n_timing * 1e6

    return err, timeit(xla), timeit(pal), r


def paged_kernel_bench():
    """Registered in benchmarks.run as ``paged_kernel_bench``."""
    import jax

    from repro.configs import get_config
    from repro.core.nvr.engine.sweep import write_artifacts
    from repro.models import api

    from dataclasses import replace

    # the reduced smoke config, scaled back up where it matters for this
    # measurement: a capacity-sized pool (production pools are sized for
    # max_len x max_batch, not current load), 4 layers, head_dim 64 —
    # the per-call k/v/s round-trip the undonated path pays is ~14 MiB
    cfg = replace(get_config("qwen2-1.5b").reduced(),
                  n_layers=4, head_dim=64, kv_page=8)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = max(16, int(32 * SCALE))
    workload = _workload(cfg, n_req)

    pre, pre_wall = _run_engine(cfg, params, workload,
                                donate_pools=False, row_bucketing=False)
    post, post_wall = _run_engine(cfg, params, workload)

    # sanity: the fast path must not change anyone's output
    for rid in pre.requests:
        a, b = pre.requests[rid], post.requests[rid]
        assert a.out_tokens == b.out_tokens, f"rid {rid} diverged"

    m_pre, m_post = pre.metrics(), post.metrics()
    pre_tps = m_pre["tokens_out"] / pre_wall
    post_tps = m_post["tokens_out"] / post_wall
    # the copies donation eliminated: without donation every decode step
    # and every executed prefill chunk materialises fresh k/v/s pools
    jit_calls = pre.stats.steps + pre.stats.prefill_calls
    pool_bytes = (pre.pool_cfg.pool_bytes                # K+V pools
                  + pre.s_pool.size * pre.s_pool.dtype.itemsize)
    copy_mib = jit_calls * pool_bytes / 2 ** 20

    err, us_xla, us_pal, r_k = _kernel_parity_and_timing(cfg, post)
    assert err < 1e-5, f"pallas/XLA parity broke: max_err={err}"

    rows = [
        ("pre_pr_path", m_pre["tokens_out"], f"{pre_wall:.3f}",
         f"{pre_tps:.1f}", m_pre["n_decode_traces"],
         m_pre["decode_rows_padded"]),
        ("donated_bucketed", m_post["tokens_out"], f"{post_wall:.3f}",
         f"{post_tps:.1f}", m_post["n_decode_traces"],
         m_post["decode_rows_padded"]),
        ("kernel_xla_us", r_k, f"{us_xla:.0f}", "", "", ""),
        ("kernel_pallas_interpret_us", r_k, f"{us_pal:.0f}", "", "", ""),
    ]
    headline = {
        "n_requests": float(n_req),
        "tok_per_s_pre_pr": pre_tps,
        "tok_per_s_donated_bucketed": post_tps,
        "step_loop_speedup_x": post_tps / pre_tps,
        "pool_copy_mib_eliminated": copy_mib,
        "decode_rows_padded_pre": float(m_pre["decode_rows_padded"]),
        "decode_rows_padded_post": float(m_post["decode_rows_padded"]),
        "n_decode_traces_post": float(m_post["n_decode_traces"]),
        "kernel_parity_max_err": err,
        "xla_oracle_us_per_call": us_xla,
        "pallas_interpret_us_per_call": us_pal,
        "paper": "NVR runahead kernel on the serve pool layout; step-loop "
                 "speedup from donation + row bucketing (CPU measurement "
                 "dominated by pool-copy / padded-row elimination)",
    }
    write_artifacts(
        "paged_kernel_bench",
        "config,tokens_or_rows,wall_s_or_us,tok_per_s,decode_traces,"
        "rows_padded", rows, results_dir(), scale=SCALE)
    return rows, headline


def main() -> None:
    rows, headline = paged_kernel_bench()
    print(f"paged_kernel_bench: {len(rows)} rows")
    for k, v in headline.items():
        print(f"    {k:34s} {v:.4g}" if isinstance(v, float)
              else f"    {k:34s} {v}")


if __name__ == "__main__":
    main()
