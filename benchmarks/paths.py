"""Canonical bench-artifact location.

Every benchmark artifact — per-row CSV/JSON pairs from the sweep
writer, sweep grids, and the committed-format ``BENCH_<name>.json``
perf-trajectory files — lands in **one** directory, resolved here and
nowhere else.  Default: ``benchmarks/results/`` next to this file.
Override with the ``BENCH_RESULTS_DIR`` environment variable (tests
point it at a tmpdir so harness self-tests never pollute the real
results; CI could point it at a per-job scratch dir).

Resolution happens at *write* time, not import time, so a test may set
the env var after the bench modules are imported.
"""

from __future__ import annotations

import os

_DEFAULT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "results")


def results_dir() -> str:
    """The canonical artifact directory, created on first use."""
    d = os.environ.get("BENCH_RESULTS_DIR") or _DEFAULT
    os.makedirs(d, exist_ok=True)
    return d
