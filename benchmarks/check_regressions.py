"""Bench regression gate: current ``BENCH_<name>.json`` vs committed
baselines.

CI's slow job runs the bench smoke at ``BENCH_SCALE=0.25``, then runs
this checker over the artifacts in ``benchmarks/results/`` against the
baselines committed under ``benchmarks/baselines/``.  Only metrics
listed in :data:`GATES` are compared — deterministic quantities
(iteration counts, hit rates, scheduler-tick latencies, improvement
ratios), never wall-clock throughput, which is hostile to shared CI
runners.  A gated metric that moves more than ``--threshold`` (default
15%) in its bad direction fails the job.

Baselines are only comparable at the scale they were recorded at: a
results file whose ``bench_scale`` differs from its baseline's is
skipped with a warning (local runs default to ``BENCH_SCALE=0.5``).

To accept an intentional perf change, re-record and commit:

    PYTHONPATH=src BENCH_SCALE=0.25 python -m benchmarks.run <benches>
    python -m benchmarks.check_regressions --update-baselines
    git add benchmarks/baselines/

See benchmarks/README.md for the artifact schema and the gate table.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

from .paths import results_dir

HERE = os.path.dirname(os.path.abspath(__file__))
BASELINES = os.path.join(HERE, "baselines")

# bench -> {headline metric: direction in which BIGGER is BETTER
# ("higher") or SMALLER is BETTER ("lower")}.  Deterministic metrics
# only: seeds are fixed, so these reproduce bit-for-bit per scale.
GATES: dict[str, dict[str, str]] = {
    "engine_speedup": {
        "parity_ok": "higher",                       # 1.0 = bit-exact
    },
    "capture_roundtrip": {
        "serve_nsb_hot_hit_rate": "higher",
        "serve_nvr_miss_reduction": "higher",
        "moe_nvr_miss_reduction": "higher",
    },
    "serve_bench": {
        "mean_latency_speedup_x": "higher",
        "p50_latency_iters": "lower",
        "nsb_hot_hit_rate": "higher",
    },
    "prefix_bench": {
        "prefill_token_savings_pct": "higher",
        "cached_page_hit_rate": "higher",
        "p50_ttft_shared": "lower",
    },
    "paged_kernel_bench": {
        "decode_rows_padded_post": "lower",
        "n_decode_traces_post": "lower",
        "pool_copy_mib_eliminated": "higher",
    },
    "runahead_bench": {
        "nsb_hit_rate_nvr": "higher",
        "nsb_hit_rate_lift_nvr_vs_off": "higher",
        "runahead_accuracy_nvr": "higher",
        "modeled_stall_cycles_per_tok_nvr": "lower",
        "modeled_tok_throughput_gain_nvr_vs_off": "higher",
    },
    "spill_bench": {
        "resume_ttft_improvement_x": "higher",
        "p50_resume_ttft_swap": "lower",
        "p99_resume_ttft_swap": "lower",
        "iterations_swap": "lower",
        "fetch_backs_swap_ra": "higher",
        "int8_dequant_error_bound": "lower",
    },
    "overlap_bench": {
        "bitwise_parity": "higher",              # 1.0 = asserted in-run
        "tpot_p99_improvement_x": "higher",
        "p99_tpot_modeled_async": "lower",
        "p99_ttft_modeled_async": "lower",
        "overlap_fraction": "higher",
        "plan_reuse_fraction": "higher",
    },
    "moe_serve_bench": {
        "expert_nsb_hit_rate_paged_router": "higher",
        "expert_hit_rate_lift_router_vs_lru": "higher",
        "expert_runahead_accuracy": "higher",
        "modeled_stall_cycles_per_tok_paged_router": "lower",
        "modeled_tok_throughput_gain_router_vs_lru": "higher",
        "preemptions": "higher",     # the bench must keep covering eviction
    },
    "workload_bench": {
        "multiturn_bitwise_parity": "higher",    # 1.0 = asserted in-run
        "slo_attainment_slo_fair": "higher",
        "slo_attainment_gain": "higher",
        "p99_ttft_slo_tenants_slo_fair": "lower",
        "prefill_tokens_skipped": "higher",  # cross-turn reuse stays live
        "nsb_hit_rate_realistic": "higher",
    },
}


def _load(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def check_bench(name: str, threshold: float,
                results: str | None = None,
                baselines_dir: str = BASELINES) -> list[str]:
    """Compare one bench's artifact against its baseline; returns a list
    of failure messages (empty = clean)."""
    fname = f"BENCH_{name}.json"
    cur = _load(os.path.join(results or results_dir(), fname))
    base = _load(os.path.join(baselines_dir, fname))
    if cur is None:
        return [f"{name}: no results artifact ({fname}) — did the "
                f"bench run?"]
    if base is None:
        return [f"{name}: no committed baseline — record one with "
                f"--update-baselines and commit benchmarks/baselines/"]
    if cur.get("bench_scale") != base.get("bench_scale"):
        print(f"  {name}: SKIP (scale {cur.get('bench_scale')} != "
              f"baseline scale {base.get('bench_scale')})")
        return []
    failures = []
    ch, bh = cur.get("headline", {}), base.get("headline", {})
    for metric, direction in GATES[name].items():
        if metric not in bh or bh[metric] is None:
            print(f"  {name}.{metric}: WARN no baseline value "
                  f"(new metric?)")
            continue
        if metric not in ch or ch[metric] is None:
            failures.append(f"{name}.{metric}: missing from current "
                            f"results (gated metric removed?)")
            continue
        b, c = float(bh[metric]), float(ch[metric])
        bad = (b - c) if direction == "higher" else (c - b)
        rel = bad / max(abs(b), 1e-12)
        status = "OK"
        if rel > threshold:
            status = "FAIL"
            failures.append(
                f"{name}.{metric}: {b:.6g} -> {c:.6g} "
                f"({rel:+.1%} worse, limit {threshold:.0%}, "
                f"{direction} is better)")
        print(f"  {name}.{metric}: {b:.6g} -> {c:.6g}  [{status}]")
    return failures


def update_baselines(names, results: str | None = None,
                     baselines_dir: str = BASELINES) -> int:
    os.makedirs(baselines_dir, exist_ok=True)
    copied = 0
    for name in names:
        src = os.path.join(results or results_dir(), f"BENCH_{name}.json")
        if not os.path.exists(src):
            print(f"  {name}: no results artifact, skipped")
            continue
        shutil.copy(src, os.path.join(baselines_dir,
                                      f"BENCH_{name}.json"))
        print(f"  {name}: baseline updated")
        copied += 1
    print(f"{copied} baseline(s) written to {baselines_dir} — "
          f"commit them.")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate BENCH_<name>.json headlines against "
                    "committed baselines")
    ap.add_argument("benches", nargs="*",
                    help=f"benches to check (default: all gated: "
                         f"{', '.join(GATES)})")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated relative regression "
                         "(default 0.15)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy current results over the committed "
                         "baselines instead of checking")
    args = ap.parse_args(argv)
    names = args.benches or list(GATES)
    unknown = [n for n in names if n not in GATES]
    if unknown:
        print(f"no gate defined for: {', '.join(unknown)}\n"
              f"gated benches: {', '.join(GATES)}", file=sys.stderr)
        return 2
    if args.update_baselines:
        return update_baselines(names)
    failures = []
    for name in names:
        failures.extend(check_bench(name, args.threshold))
    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nall gated benches within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
